"""Shape inference + liveness analysis + linear-scan peak memory — §3.2/§3.3.

The paper estimates a branch's peak memory ``M_i`` in three steps:

1. *shape inference* — tensor sizes from operator metadata (our TensorSpecs
   are static already; symbolic dims are sized by their upper bound),
2. *liveness analysis* — each tensor's lifetime interval within the branch;
   tensors needed downstream remain active,
3. *linear scan* over interval endpoints maintaining a running total,
   recording the peak.  O(|V|) and fused with branch identification.

Lifetime convention: a tensor is live at step ``i`` iff
``def_idx <= i <= last_use_idx`` — node ``i``'s inputs and outputs are
simultaneously live while it executes.
"""

from __future__ import annotations

from dataclasses import dataclass

from .graph import Graph


@dataclass(frozen=True)
class Lifetime:
    tensor: int
    start: int      # index of the defining node in the execution order
    end: int        # index of the last-using node (inclusive)
    nbytes: int


def tensor_lifetimes(graph: Graph, order: "list[int]",
                     escape_live_to_end: bool = True) -> "list[Lifetime]":
    """Lifetimes of tensors *produced* by nodes in ``order``.

    ``order`` is any execution order (full graph topo order, or one
    branch's node list).  Tensors consumed by nodes outside ``order`` —
    "needed downstream" — or listed as graph outputs stay live to the end
    of the window when ``escape_live_to_end`` (paper §3.3).
    Graph inputs and params are excluded: the arena holds temporary
    activations, not static model memory (paper Table 4's split).
    """
    pos = {nid: i for i, nid in enumerate(order)}
    in_window = set(order)
    graph_outputs = set(graph.outputs)

    consumers: dict[int, list] = {}
    for n in graph.nodes.values():
        for t in n.inputs:
            consumers.setdefault(t, []).append(n.id)

    lifetimes: list[Lifetime] = []
    for nid in order:
        node = graph.nodes[nid]
        for t in node.outputs:
            start = pos[nid]
            end = start
            escapes = t in graph_outputs
            for c in consumers.get(t, ()):  # last use
                if c in in_window:
                    end = max(end, pos[c])
                else:
                    escapes = True
            if escapes and escape_live_to_end:
                end = len(order) - 1
            lifetimes.append(
                Lifetime(t, start, end, graph.tensors[t].nbytes()))
    return lifetimes


def peak_memory_linear_scan(lifetimes: "list[Lifetime]") -> int:
    """Linear sweep over interval endpoints (paper §3.3, O(|V|))."""
    if not lifetimes:
        return 0
    horizon = max(lt.end for lt in lifetimes) + 2
    delta = [0] * horizon
    for lt in lifetimes:
        delta[lt.start] += lt.nbytes
        delta[lt.end + 1] -= lt.nbytes
    peak = 0
    running = 0
    for d in delta:
        running += d
        peak = max(peak, running)
    return peak


def peak_memory_bruteforce(lifetimes: "list[Lifetime]") -> int:
    """O(V^2) oracle used by property tests against the linear scan."""
    if not lifetimes:
        return 0
    peak = 0
    for i in range(max(lt.end for lt in lifetimes) + 1):
        peak = max(peak, sum(lt.nbytes for lt in lifetimes
                             if lt.start <= i <= lt.end))
    return peak


def branch_peak_memory(graph: Graph, branch_nodes: "list[int]") -> int:
    """M_i: estimated peak memory of one branch (paper §3.3)."""
    return peak_memory_linear_scan(tensor_lifetimes(graph, branch_nodes))


def lifetimes_overlap(a: Lifetime, b: Lifetime) -> bool:
    """reuse(Tj, Tk) ⟺ lifetime(Tj) ∩ lifetime(Tk) = ∅  (Eq. 1)."""
    return not (a.end < b.start or b.end < a.start)
