"""Workload refinement — paper §3.1 "Further Refinement".

For a layer's branches to execute in parallel, each branch must satisfy

    N > 2    and    F_max / F_min <= beta        (beta = 1.5 in experiments)

i.e. minimal per-branch workload and bounded imbalance (otherwise the
lightest thread idles at the layer barrier — or, in our TPU adaptation,
the branch-batched kernel pads too much: padding waste <= (beta-1)/beta).

``group_layer`` partitions one layer's branches into *balanced parallel
groups* (each of size >= 2, ratio-bounded) plus a sequential remainder.
Delegate branches are exempt from the N > 2 floor: a fused delegate region
already aggregates >= min_ops ops (its node count is carried in attrs).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .classify import Branch

DEFAULT_BETA = 1.5
MIN_BRANCH_OPS = 2  # paper: N > 2


@dataclass
class LayerGroups:
    """Execution structure of one layer after refinement."""

    parallel_groups: "list[list[int]]" = field(default_factory=list)
    sequential: "list[int]" = field(default_factory=list)

    def max_width(self) -> int:
        return max((len(g) for g in self.parallel_groups), default=1)


def group_layer(branches: "dict[int, Branch]", layer: "list[int]",
                beta: float = DEFAULT_BETA) -> LayerGroups:
    """Greedy balanced grouping of one layer's branches.

    Branches are sorted by descending F; a group absorbs subsequent branches
    while ``F_max / F_min <= beta``.  Groups that end up singleton, and
    branches failing the N floor, run sequentially.
    """
    out = LayerGroups()
    eligible = []
    for bid in layer:
        b = branches[bid]
        if b.n_ops > MIN_BRANCH_OPS or b.delegate:
            eligible.append(bid)
        else:
            out.sequential.append(bid)
    eligible.sort(key=lambda bid: (-branches[bid].flops, bid))

    i = 0
    while i < len(eligible):
        f_max = max(branches[eligible[i]].flops, 1.0)
        j = i + 1
        while j < len(eligible):
            f_min = max(branches[eligible[j]].flops, 1.0)
            if f_max / f_min > beta:
                break
            j += 1
        group = eligible[i:j]
        if len(group) >= 2:
            out.parallel_groups.append(sorted(group))
        else:
            out.sequential.extend(group)
        i = j
    out.sequential.sort()
    return out


def balance_ratio(branches: "dict[int, Branch]", group: "list[int]") -> float:
    fs = [max(branches[b].flops, 1.0) for b in group]
    return max(fs) / min(fs)
