"""Branch-aware memory management — paper §3.2.

Every branch ``b_i`` gets a dedicated memory **arena** ``A_i``; all tensor
allocations of the branch stay inside ``A_i`` (no cross-branch conflicts,
safe parallelism).  Within an arena Parallax uses a *bump-pointer allocator
with liveness analysis*: when a tensor's last use completes its buffer is
reclaimed into a free list for reuse — legal because

    reuse(Tj, Tk)  ⟺  lifetime(Tj) ∩ lifetime(Tk) = ∅        (Eq. 1)

Cross-arena sharing: freed storage of a branch in an earlier,
non-concurrent layer may back a later branch's arena (``SlabPool``).
Dynamic tensors are sized at their upper bound and confined to the
originating branch's arena (§3.2 "Handling Dynamic Tensor Shapes").
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field

from .graph import Graph
from .liveness import Lifetime, peak_memory_linear_scan, tensor_lifetimes

ALIGN = 64  # byte alignment of every allocation


def _align(n: int, a: int = ALIGN) -> int:
    return (n + a - 1) // a * a


class BumpAllocator:
    """Bump pointer + coalescing best-fit free list (one arena).

    Two mirrored views of the free set are kept in sync: ``free_list``
    sorted by offset (for O(1) neighbor coalescing on free) and
    ``_by_size`` sorted by (size, offset) (for O(log n) best-fit on
    allocate, mirroring ``SlabPool._KEY``).  Ties on size resolve to the
    lowest offset — the same block the previous linear scan chose.
    """

    def __init__(self) -> None:
        self.bump = 0
        self.free_list: list[tuple] = []   # sorted [(offset, size), ...]
        self._by_size: list[tuple] = []    # sorted [(size, offset), ...]
        self.reuse_hits = 0

    def allocate(self, size: int) -> int:
        size = _align(max(size, 1))
        # Best-fit via the size-ordered index (paper: "reclaimed into a
        # free list for reuse by subsequent tensors").
        i = bisect.bisect_left(self._by_size, (size, -1))
        if i < len(self._by_size):
            sz, off = self._by_size.pop(i)
            j = bisect.bisect_left(self.free_list, (off, sz))
            self.free_list.pop(j)
            if sz > size:
                bisect.insort(self.free_list, (off + size, sz - size))
                bisect.insort(self._by_size, (sz - size, off + size))
            self.reuse_hits += 1
            return off
        off = self.bump
        self.bump += size
        return off

    def free(self, offset: int, size: int) -> None:
        """O(log n) insert + O(1) coalescing with the two adjacent blocks
        (the list stays sorted by offset, so neighbors are the only merge
        candidates — no full re-sort per free)."""
        size = _align(max(size, 1))
        lst = self.free_list
        i = bisect.bisect_left(lst, (offset, size))
        start, end = offset, offset + size
        if i > 0 and lst[i - 1][0] + lst[i - 1][1] == start:
            i -= 1
            o, s = lst.pop(i)
            start = o
            self._drop_size(s, o)
        if i < len(lst) and lst[i][0] == end:
            o, s = lst.pop(i)
            end += s
            self._drop_size(s, o)
        lst.insert(i, (start, end - start))
        bisect.insort(self._by_size, (end - start, start))

    def _drop_size(self, size: int, offset: int) -> None:
        j = bisect.bisect_left(self._by_size, (size, offset))
        self._by_size.pop(j)

    @property
    def high_water(self) -> int:
        return self.bump


@dataclass
class ArenaPlan:
    """Buffer plan of one branch arena: tensor id -> (offset, size)."""

    branch_id: int
    offsets: "dict[int, tuple]" = field(default_factory=dict)
    size: int = 0                      # arena high-water (allocated bytes)
    peak_live: int = 0                 # liveness lower bound (Σ live bytes)
    reuse_hits: int = 0

    def overlap_pairs(self, lifetimes: "list[Lifetime]") -> "list[tuple]":
        """Pairs of simultaneously-live tensors whose buffers overlap —
        must be empty for a correct plan (test helper)."""
        by_id = {lt.tensor: lt for lt in lifetimes}
        bad = []
        items = sorted(self.offsets.items())
        for i, (t1, (o1, s1)) in enumerate(items):
            for t2, (o2, s2) in items[i + 1:]:
                l1, l2 = by_id[t1], by_id[t2]
                live_both = not (l1.end < l2.start or l2.end < l1.start)
                mem_overlap = not (o1 + s1 <= o2 or o2 + s2 <= o1)
                if live_both and mem_overlap:
                    bad.append((t1, t2))
        return bad


def plan_branch_arena(graph: Graph, branch_id: int,
                      branch_nodes: "list[int]",
                      naive: bool = False) -> "tuple[ArenaPlan, list]":
    """Compute the arena layout of one branch (§3.2 in-branch reuse).

    Walks the branch in execution order: allocate each node's outputs at
    its step, free buffers whose last use has completed.  ``naive=True``
    disables the free list (every tensor gets separate memory) — the
    paper's "Naive" baseline in Table 5.

    Returns ``(plan, lifetimes)``.
    """
    lifetimes = tensor_lifetimes(graph, branch_nodes)
    by_step_alloc: dict[int, list] = {}
    by_step_free: dict[int, list] = {}
    for lt in lifetimes:
        by_step_alloc.setdefault(lt.start, []).append(lt)
        by_step_free.setdefault(lt.end, []).append(lt)

    alloc = BumpAllocator()
    plan = ArenaPlan(branch_id)
    for step in range(len(branch_nodes)):
        for lt in by_step_alloc.get(step, ()):
            off = alloc.allocate(lt.nbytes)
            plan.offsets[lt.tensor] = (off, _align(max(lt.nbytes, 1)))
        if not naive:
            for lt in by_step_free.get(step, ()):
                off, sz = plan.offsets[lt.tensor]
                alloc.free(off, sz)
    plan.size = alloc.high_water
    plan.peak_live = peak_memory_linear_scan(lifetimes)
    plan.reuse_hits = alloc.reuse_hits
    return plan, lifetimes


def plan_global_arena(graph: Graph, order: "list[int]") -> ArenaPlan:
    """TFLite/ORT-style single global arena with aggressive reuse.

    The paper contrasts this with branch arenas: global reuse minimizes
    memory but "creates data dependencies that block branch-level
    parallelism" (§2).  Used as the SOTA-baseline memory planner in
    benchmarks (Tables 4/5).
    """
    lifetimes = tensor_lifetimes(graph, order)
    by_step_alloc: dict[int, list] = {}
    by_step_free: dict[int, list] = {}
    for lt in lifetimes:
        by_step_alloc.setdefault(lt.start, []).append(lt)
        by_step_free.setdefault(lt.end, []).append(lt)
    alloc = BumpAllocator()
    plan = ArenaPlan(-1)
    for step in range(len(order)):
        for lt in by_step_alloc.get(step, ()):
            off = alloc.allocate(lt.nbytes)
            plan.offsets[lt.tensor] = (off, _align(max(lt.nbytes, 1)))
        for lt in by_step_free.get(step, ()):
            off, sz = plan.offsets[lt.tensor]
            alloc.free(off, sz)
    plan.size = alloc.high_water
    plan.peak_live = peak_memory_linear_scan(lifetimes)
    plan.reuse_hits = alloc.reuse_hits
    return plan


@dataclass
class Slab:
    id: int
    size: int


class SlabPool:
    """Cross-arena buffer sharing (§3.2).

    Branch arenas from non-concurrent layers reuse each other's backing
    storage: when a layer finishes, its slabs return to the pool and later
    layers draw from it.  ``peak_bytes`` is the real footprint of all
    arenas combined; ``sum_of_arena_sizes`` would be the no-sharing cost.
    """

    _KEY = staticmethod(lambda s: (s.size, s.id))

    def __init__(self) -> None:
        self._free: list[Slab] = []     # sorted by (size, id): best fit is
        self._next = 0                  # the first adequate slab
        self.total_allocated = 0
        self.in_use = 0
        self.peak_bytes = 0
        self.reuse_count = 0

    def acquire(self, size: int) -> Slab:
        size = _align(max(size, 1))
        i = bisect.bisect_left(self._free, (size, -1), key=self._KEY)
        if i < len(self._free):
            slab = self._free.pop(i)
            self.reuse_count += 1
        else:
            slab = Slab(self._next, size)
            self._next += 1
            self.total_allocated += size
        self.in_use += slab.size
        self.peak_bytes = max(self.peak_bytes, self.total_allocated)
        return slab

    def release(self, slab: Slab) -> None:
        self.in_use -= slab.size
        bisect.insort(self._free, slab, key=self._KEY)
