"""Layer construction via topological sort — paper §3.1, Alg. 2 / 4.

Branches are grouped into *layers*: all branches in a layer have had every
dependency satisfied by earlier layers, so branches within one layer are
mutually independent and may execute in parallel (subject to the §3.1
refinement and the §3.3 memory-budget schedule).
"""

from __future__ import annotations

from .classify import Branch, branch_dependencies
from .graph import Graph


def build_layers(graph: Graph, branches: "list[Branch]") -> "list[list[int]]":
    """Kahn-style level construction (Algorithm 2 / Algorithm 4).

    Returns a list of layers; each layer is a sorted list of branch ids.
    """
    deps, rdeps = branch_dependencies(graph, branches)
    d = {b.id: len(rdeps[b.id]) for b in branches}          # in-degree map
    queue = sorted(bid for bid, deg in d.items() if deg == 0)
    layers: list[list[int]] = []
    emitted = 0
    while queue:
        layer = list(queue)                                  # layer <- Q
        queue = []
        for bid in layer:                                    # process branch b
            for dep in sorted(deps[bid]):                    # b' dependent on b
                d[dep] -= 1
                if d[dep] == 0:
                    queue.append(dep)
        queue.sort()
        layers.append(sorted(layer))
        emitted += len(layer)
    if emitted != len(branches):
        raise ValueError("branch dependency graph has a cycle")
    return layers


def validate_layers(graph: Graph, branches: "list[Branch]",
                    layers: "list[list[int]]") -> None:
    """Asserts the defining layer property: no intra-layer dependencies and
    every dependency points to a strictly earlier layer."""
    deps, _ = branch_dependencies(graph, branches)
    level = {}
    for li, layer in enumerate(layers):
        for bid in layer:
            level[bid] = li
    for bid, succs in deps.items():
        for s in succs:
            if level[s] <= level[bid]:
                raise AssertionError(
                    f"branch {s} (layer {level[s]}) depends on branch {bid} "
                    f"(layer {level[bid]}) but is not in a later layer")
