"""Schedule compilation — lowering an ExecutionPlan to fused callables.

The interpreted executor walks the §3.3 schedule group-by-group: every
parallel group and every sequential branch is its own jitted callable, so
one run issues O(groups x layers) host dispatches and (historically)
synchronized after every layer.  On the fine-grained graphs the paper
targets, dispatch overhead then dominates exactly the branch parallelism
Parallax exposes (cf. Opara's schedule-capture argument in PAPERS.md).

This module makes the *schedule* the unit of dispatch instead:

* **Per-layer fusion** — each :class:`~repro.core.scheduler.ScheduledLayer`
  (all of its parallel groups plus its sequential branches) is traced into
  ONE ``jax.jit`` callable.  A run issues O(layers) dispatches; XLA sees
  every branch of the layer in one computation and can schedule them
  concurrently.
* **Whole-plan fusion** — opt-in (``whole_plan=True``): the entire schedule
  lowers to a single callable (one dispatch per run) for steady-state
  inference.
* **Homogeneous-group batching** — a balanced group whose branches share
  chain length and whose chain position p is a *pure* 2-D matmul with
  identical shapes across branches (the β-balance refinement of §3.1 makes
  this the common case: attention heads, expert MLPs) lowers position p to
  one grouped ``branch_matmul`` Pallas GEMM ``(G, M, K) x (G, K, N)``
  instead of G separate dots.  Purity is decided by jaxpr equality against
  ``jnp.dot``, so epilogue-fused node fns (``tanh(dot)``) are never
  mis-batched.
* **Donated intermediates** — layer inputs produced by an earlier layer and
  dead afterwards are marked in ``donate_argnums`` so XLA may reuse their
  buffers (applied when the backend supports donation; argnums are always
  recorded for inspection).
* **Compile cache** — compiled schedules are keyed on
  :func:`~repro.core.plan.plan_signature` within a weak-keyed per-graph
  scope, so repeated runs and fresh executors over an identical plan
  signature reuse the same callables and never re-trace, while two graph
  objects never share artifacts (fn fingerprints reduce closure-captured
  weights to metadata, so cross-graph sharing could bake one graph's
  constants into another's results).
"""

from __future__ import annotations

import weakref
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .graph import Graph, Node, region_boundary_tensors
from .plan import ExecutionPlan, fn_fingerprint, plan_signature

try:  # grouped Pallas GEMM; gate batching off if pallas is unavailable
    from ..kernels.branch_matmul.ops import grouped_branch_matmul
except Exception:  # pragma: no cover - stripped-down installs
    grouped_branch_matmul = None


# --------------------------------------------------------------------------
# Pure-matmul detection (homogeneous-group batching eligibility)
# --------------------------------------------------------------------------

_PURE_MM_CACHE: dict = {}


def _is_pure_matmul(graph: Graph, node: Node) -> bool:
    """True iff ``node.fn`` computes exactly ``jnp.dot(x, w)`` on 2-D inputs.

    Decided by jaxpr equality on the node's static shapes, cached per
    (fn fingerprint, shapes).  This is what keeps epilogue-fused matmul
    nodes (``tanh(dot)``, ``dot * 0.1``) off the grouped-GEMM path.
    """
    if (node.op_class != "matmul" or node.fn is None
            or len(node.inputs) != 2 or len(node.outputs) != 1):
        return False
    x_spec = graph.tensors[node.inputs[0]].spec
    w_spec = graph.tensors[node.inputs[1]].spec
    if len(x_spec.static_shape) != 2 or len(w_spec.static_shape) != 2:
        return False
    if x_spec.is_dynamic or w_spec.is_dynamic:
        return False
    key = (fn_fingerprint(node.fn), x_spec.static_shape, x_spec.dtype,
           w_spec.static_shape, w_spec.dtype)
    if key not in _PURE_MM_CACHE:
        xa = jax.ShapeDtypeStruct(x_spec.static_shape, x_spec.dtype)
        wa = jax.ShapeDtypeStruct(w_spec.static_shape, w_spec.dtype)
        try:
            got = str(jax.make_jaxpr(node.fn)(xa, wa))
            ref = str(jax.make_jaxpr(lambda a, b: jnp.dot(a, b))(xa, wa))
            _PURE_MM_CACHE[key] = got == ref
        except Exception:
            _PURE_MM_CACHE[key] = False
    return _PURE_MM_CACHE[key]


def gemm_positions(plan: ExecutionPlan, group: "list[int]") -> "tuple[int, ...]":
    """Chain positions of a balanced group lowered to one grouped GEMM.

    Requires every branch in the group to have the same chain length, and —
    at a given position — every branch's node to be a pure 2-D matmul with
    identical operand shapes/dtypes.  Positions that fail stay per-branch
    (they still fuse into the layer callable; they just don't batch).
    """
    g = plan.graph
    chains = [plan.branches[b].nodes for b in group]
    length = len(chains[0])
    if len(group) < 2 or any(len(c) != length for c in chains):
        return ()
    out = []
    for pos in range(length):
        nodes = [g.nodes[c[pos]] for c in chains]
        if not all(_is_pure_matmul(g, n) for n in nodes):
            continue
        shapes = {(g.tensors[n.inputs[0]].spec.static_shape,
                   g.tensors[n.inputs[1]].spec.static_shape,
                   g.tensors[n.inputs[0]].spec.dtype,
                   g.tensors[n.inputs[1]].spec.dtype) for n in nodes}
        if len(shapes) == 1:
            out.append(pos)
    return tuple(out)


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class CompileStats:
    """Static facts about a lowered schedule (asserted by tests/benchmarks)."""

    layers: int              # fused dispatches per run (per-layer mode)
    units: int               # groups + sequential branches = interpreted dispatches
    batched_groups: int      # balanced groups routed through branch_matmul
    gemm_sites: int          # chain positions lowered to grouped GEMMs


@dataclass
class CompiledLayer:
    layer_index: int
    fn: Callable                   # jitted: (*in arrays) -> tuple(out arrays)
    in_ids: "tuple[int, ...]"
    out_ids: "tuple[int, ...]"
    width: int
    donate_argnums: "tuple[int, ...]"   # recorded even when donation is off


@dataclass
class CompiledSchedule:
    layers: "list[CompiledLayer]"
    whole: "CompiledLayer | None"       # set when whole_plan=True
    stats: CompileStats
    use_branch_kernel: bool
    donate: bool

    def dispatches_per_run(self) -> int:
        return 1 if self.whole is not None else len(self.layers)


@dataclass
class CompiledSegment:
    """One fused callable per (scheduled layer, logical device).

    ``dynamic=True`` segments carry no callable: they are control-flow
    regions the heterogeneous runtime executes host-side through
    ``repro.hetero.dynamic`` (per-subgraph compile cache) instead of
    tracing them into a fused computation.
    """

    layer_index: int
    device: "tuple[str, int]"           # logical (kind, index)
    fn: "Callable | None"               # None for dynamic segments
    in_ids: "tuple[int, ...]"
    out_ids: "tuple[int, ...]"
    width: int
    branch_ids: "tuple[int, ...]"
    node_ids: "tuple[int, ...]" = ()    # set for dynamic segments
    dynamic: bool = False


@dataclass(frozen=True)
class HeteroCompileStats:
    segments: int             # dispatches per run (incl. dynamic regions)
    dynamic_regions: int
    devices: "tuple[tuple, ...]"        # logical devices with >= 1 segment
    batched_groups: int       # groups intact on one device AND batchable
    gemm_sites: int


@dataclass
class CompiledHeteroSchedule:
    segments: "list[CompiledSegment]"   # layer-major, device-sorted
    stats: HeteroCompileStats
    use_branch_kernel: bool

    def dispatches_per_run(self) -> int:
        return len(self.segments)

    def segments_on(self, device: "tuple[str, int]"):
        return [s for s in self.segments if s.device == device]


def _apply_node(env: dict, node: Node) -> None:
    outs = node.fn(*[env[t] for t in node.inputs])
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    for t, v in zip(node.outputs, outs):
        env[t] = v


def _run_layer_traced(plan: ExecutionPlan, sl, env: dict,
                      batch_map: "dict[tuple, frozenset]") -> None:
    """Emit one scheduled layer into the current trace."""
    g = plan.graph
    for group in sl.parallel_groups:
        positions = batch_map.get(tuple(group), frozenset())
        if positions:
            chains = [plan.branches[b].nodes for b in group]
            for pos in range(len(chains[0])):
                nodes = [g.nodes[c[pos]] for c in chains]
                if pos in positions:
                    xs = [env[n.inputs[0]] for n in nodes]
                    ws = [env[n.inputs[1]] for n in nodes]
                    for n, o in zip(nodes, grouped_branch_matmul(xs, ws)):
                        env[n.outputs[0]] = o
                else:
                    for n in nodes:
                        _apply_node(env, n)
        else:
            for b in group:
                for nid in plan.branches[b].nodes:
                    _apply_node(env, g.nodes[nid])
    for b in sl.sequential:
        for nid in plan.branches[b].nodes:
            _apply_node(env, g.nodes[nid])


def _batch_map(plan: ExecutionPlan,
               use_branch_kernel: bool) -> "dict[tuple, frozenset]":
    if not use_branch_kernel or grouped_branch_matmul is None:
        return {}
    out = {}
    for sl in plan.schedule.layers:
        for group in sl.parallel_groups:
            positions = gemm_positions(plan, group)
            if positions:
                out[tuple(group)] = frozenset(positions)
    return out


def _lower_region(plan: ExecutionPlan, sls: list,
                  batch_map: "dict[tuple, frozenset]"):
    """(fn, in_ids, out_ids) executing the given scheduled layers as one
    traced region with graph-level boundary inference."""
    region = {nid for sl in sls for b in sl.all_branches()
              for nid in plan.branches[b].nodes}
    in_ids, out_ids = region_boundary_tensors(plan.graph, region)

    def fn(*args):
        env = dict(zip(in_ids, args))
        for sl in sls:
            _run_layer_traced(plan, sl, env, batch_map)
        return tuple(env[t] for t in out_ids)

    return fn, tuple(in_ids), tuple(out_ids)


def _donate_argnums(plan: ExecutionPlan, per_layer_inputs: list):
    """Per layer, arg positions whose tensors die at that layer.

    A layer input is donatable iff it was produced by an earlier layer
    (i.e. it is not a caller-owned graph input / param), it is not a graph
    output, and no later layer reads it.
    """
    last_read: dict[int, int] = {}
    for idx, in_ids in enumerate(per_layer_inputs):
        for t in in_ids:
            last_read[t] = idx
    caller_owned = set(plan.graph.inputs) | set(plan.graph.params)
    outputs = set(plan.graph.outputs)
    donate = []
    for idx, in_ids in enumerate(per_layer_inputs):
        donate.append(tuple(
            i for i, t in enumerate(in_ids)
            if t not in caller_owned and t not in outputs
            and last_read[t] == idx))
    return donate


# --------------------------------------------------------------------------
# Compile cache
# --------------------------------------------------------------------------

# Scoped per graph *object* (weak-keyed): fn fingerprints deliberately reduce
# closure-captured arrays to shape/dtype metadata, so two structurally
# identical graphs closing over different weights share a signature — sharing
# compiled callables across graph objects would bake one graph's weights
# into the other's results.  Weak keying also bounds memory: a graph's
# compiled schedules are evicted when the graph itself is collected.
_COMPILE_CACHE: "weakref.WeakKeyDictionary[Graph, dict]" = (
    weakref.WeakKeyDictionary())


def clear_compile_cache() -> None:
    _COMPILE_CACHE.clear()
    _PURE_MM_CACHE.clear()


def compile_schedule(plan: ExecutionPlan, *, whole_plan: bool = False,
                     use_branch_kernel: bool = True,
                     donate: "bool | None" = None) -> CompiledSchedule:
    """Lower ``plan`` into fused callables, reusing cached compilations.

    ``donate=None`` enables buffer donation exactly when the backend
    supports it (CPU does not and would warn on every dispatch).
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"
    use_branch_kernel = use_branch_kernel and grouped_branch_matmul is not None
    per_graph = _COMPILE_CACHE.setdefault(plan.graph, {})
    key = (plan_signature(plan), whole_plan, use_branch_kernel, donate)
    cached = per_graph.get(key)
    if cached is not None:
        return cached

    batch_map = _batch_map(plan, use_branch_kernel)
    sched = plan.schedule
    units = sum(len(sl.parallel_groups) + len(sl.sequential)
                for sl in sched.layers)
    stats = CompileStats(
        layers=len(sched.layers), units=units,
        batched_groups=len(batch_map),
        gemm_sites=sum(len(p) for p in batch_map.values()))

    layers: list[CompiledLayer] = []
    whole: "CompiledLayer | None" = None
    if whole_plan:
        fn, in_ids, out_ids = _lower_region(plan, list(sched.layers),
                                            batch_map)
        whole = CompiledLayer(-1, jax.jit(fn), in_ids, out_ids,
                              sched.max_width(), ())
    else:
        lowered = [_lower_region(plan, [sl], batch_map)
                   for sl in sched.layers]
        donatable = _donate_argnums(plan, [l[1] for l in lowered])
        for sl, (fn, in_ids, out_ids), nums in zip(sched.layers, lowered,
                                                   donatable):
            jitted = jax.jit(fn, donate_argnums=nums if donate else ())
            layers.append(CompiledLayer(sl.layer_index, jitted, in_ids,
                                        out_ids, sl.width(), nums))

    compiled = CompiledSchedule(layers=layers, whole=whole, stats=stats,
                                use_branch_kernel=use_branch_kernel,
                                donate=donate)
    per_graph[key] = compiled
    return compiled


def compile_hetero_schedule(plan: ExecutionPlan, *,
                            use_branch_kernel: bool = True
                            ) -> CompiledHeteroSchedule:
    """Lower a *placed* plan into one fused callable per (layer, device).

    Each scheduled layer is split by the plan's
    :class:`~repro.hetero.placement.PlacementPlan`: branches sharing a
    logical device trace into one jitted segment; a §3.1-balanced group
    stays a parallel group (grouped-GEMM eligible) only when placement
    kept it intact on a single device — round-robined groups trade kernel
    batching for device-level parallelism.  Dynamic (control-flow)
    branches become fn-less segments executed by ``hetero/dynamic.py``.

    All branches within one scheduled layer are mutually independent (the
    §3.1 layer property), so a layer's segments may dispatch concurrently
    on their devices; the runtime orders them deterministically.  Cached
    like :func:`compile_schedule`; the plan signature already covers the
    placement.
    """
    from .scheduler import ScheduledLayer
    placement = plan.placement
    if placement is None:
        raise ValueError("plan has no placement — heterogenize() it first "
                         "(repro.hetero)")
    use_branch_kernel = use_branch_kernel and grouped_branch_matmul is not None
    per_graph = _COMPILE_CACHE.setdefault(plan.graph, {})
    key = ("hetero", plan_signature(plan), use_branch_kernel)
    cached = per_graph.get(key)
    if cached is not None:
        return cached

    batch_map = _batch_map(plan, use_branch_kernel)
    assign = placement.assignments
    segments: list[CompiledSegment] = []
    intact_batched: set = set()
    for sl in plan.schedule.layers:
        per_dev: dict[tuple, ScheduledLayer] = {}

        def pseudo(dev: tuple) -> ScheduledLayer:
            if dev not in per_dev:
                per_dev[dev] = ScheduledLayer(sl.layer_index)
            return per_dev[dev]

        dynamic_bids: list[int] = []
        for group in sl.parallel_groups:
            static = [b for b in group if not assign[b].dynamic]
            dynamic_bids.extend(b for b in group if assign[b].dynamic)
            devs = {assign[b].key for b in static}
            if static == list(group) and len(devs) == 1:
                pseudo(devs.pop()).parallel_groups.append(list(group))
                if tuple(group) in batch_map:
                    intact_batched.add(tuple(group))
            else:
                for b in static:
                    pseudo(assign[b].key).sequential.append(b)
        for b in sl.sequential:
            if assign[b].dynamic:
                dynamic_bids.append(b)
            else:
                pseudo(assign[b].key).sequential.append(b)

        for dev in sorted(per_dev):
            psl = per_dev[dev]
            fn, in_ids, out_ids = _lower_region(plan, [psl], batch_map)
            segments.append(CompiledSegment(
                sl.layer_index, dev, jax.jit(fn), in_ids, out_ids,
                psl.width(), tuple(psl.all_branches())))
        for b in sorted(dynamic_bids):
            node_ids = tuple(plan.branches[b].nodes)
            in_ids, out_ids = region_boundary_tensors(plan.graph,
                                                      set(node_ids))
            segments.append(CompiledSegment(
                sl.layer_index, assign[b].key, None, tuple(in_ids),
                tuple(out_ids), 1, (b,), node_ids, dynamic=True))

    stats = HeteroCompileStats(
        segments=len(segments),
        dynamic_regions=sum(1 for s in segments if s.dynamic),
        devices=tuple(sorted({s.device for s in segments})),
        batched_groups=len(intact_batched),
        gemm_sites=sum(len(batch_map[g]) for g in intact_batched))
    compiled = CompiledHeteroSchedule(segments=segments, stats=stats,
                                      use_branch_kernel=use_branch_kernel)
    per_graph[key] = compiled
    return compiled
