"""Parallax core: the paper's §3 algorithms as a composable library.

Public API:

    from repro.core import (GraphBuilder, compile_plan, ParallaxConfig,
                            PlanExecutor)

    g = ...  # build or export a DAG
    plan = compile_plan(g, ParallaxConfig())
    out = PlanExecutor(plan, mode="parallax")(inputs)
"""

from .arena import (ArenaPlan, BumpAllocator, SlabPool, plan_branch_arena,
                    plan_global_arena)
from .balance import DEFAULT_BETA, LayerGroups, balance_ratio, group_layer
from .classify import (Branch, annotate_workloads, branch_dependencies,
                       classify_nodes, extract_branches)
from .compile import (CompiledHeteroSchedule, CompiledLayer, CompiledSchedule,
                      CompiledSegment, CompileStats, HeteroCompileStats,
                      clear_compile_cache, compile_hetero_schedule,
                      compile_schedule, gemm_positions)
from .executor import ArenaExecutor, PlanExecutor, RunResult, make_subgraph_fn
from .flops import (attention_flops, conv2d_flops, elementwise_flops,
                    matmul_flops, misc_flops, pooling_flops, ssd_scan_flops)
from .graph import (Dim, Graph, GraphBuilder, Node, Tensor, TensorSpec,
                    fuse_region, region_boundary_tensors,
                    MERGER, SEQUENTIAL, SPLITTER, SPLIT_MERGE)
from .layers import build_layers, validate_layers
from .liveness import (Lifetime, branch_peak_memory, lifetimes_overlap,
                       peak_memory_bruteforce, peak_memory_linear_scan,
                       tensor_lifetimes)
from .partition import (CostModel, HardwareProfile, MOBILE_SOC, TPU_V5E,
                        PartitionReport, assign_epochs, candidate_regions,
                        candidate_regions_epoch,
                        partition_graph)
from .pipeline import (MOBILE_CONFIG, TPU_CONFIG, ParallaxConfig,
                       compile_plan)
from .plan import (ExecutionPlan, GraphStats, fn_fingerprint, graph_stats,
                   plan_signature)
from .scheduler import (Schedule, ScheduledLayer, greedy_select,
                        incremental_select, memory_budget,
                        query_available_memory, schedule_layers)

__all__ = [n for n in dir() if not n.startswith("_")]
