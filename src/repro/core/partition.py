"""Optimized delegate partitioning — paper §3.1 + Appendices A/B.

Identifies accelerator-worthy regions in a heterogeneous graph and prunes
delegate candidates that would lose to CPU execution.  A candidate region
``S`` is offloaded only if

    N = |V(S)| >= 3,    F = Σ FLOPs >= F_min,    B / F <= r_max

where ``B`` is the boundary-tensor transfer size.  The thresholds derive
from requiring ``T_offload = L + F/R_acc + B/B_bw < T_cpu = F/R_cpu``
(Appendix B), which simplifies to ``F > L·R_cpu`` and ``B/F < B_bw/R_acc``,
then relaxing for device variability.

Region discovery uses the epoch/convexity construction (the same family of
algorithms as TFLite's ``PartitionGraphIntoIndependentNodeSubsets``, which
the paper modifies): nodes are assigned monotonically non-decreasing epochs
that alternate supported/unsupported kinds along every path, making each
same-epoch connected component *convex* — fusing it can never create a
cycle.

Hardware profiles: the paper's mobile SoC constants are retained as
``MOBILE_SOC`` (for faithful-reproduction benchmarks); ``TPU_V5E`` re-derives
the same criterion for our target (DESIGN.md §2 — the criterion is a
roofline argument and transfers unchanged in form).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .graph import Graph, fuse_region, region_boundary_tensors


@dataclass(frozen=True)
class HardwareProfile:
    """Constants of the offload cost model (paper §3.1 / B.3)."""

    name: str
    dispatch_latency_s: float        # L
    acc_macs_per_s: float            # R_acc
    cpu_macs_per_s: float            # R_cpu
    mem_bw_bytes_per_s: float        # B_bw

    def derived_flops_floor(self) -> float:
        """F > L·R_cpu (compute-bound condition, Appendix B.2)."""
        return self.dispatch_latency_s * self.cpu_macs_per_s

    def derived_bytes_per_mac(self) -> float:
        """B/F < B_bw/R_acc (memory-bound condition, Appendix B.2)."""
        return self.mem_bw_bytes_per_s / self.acc_macs_per_s


# Paper §3.1 representative values: NNAPI burst dispatch 0.2 ms, Snapdragon
# 8 Gen 1 accelerator 2.6e13 MAC/s, LPDDR5 51.2 GB/s, mobile CPU ~1e9 MAC/s.
MOBILE_SOC = HardwareProfile("mobile-soc", 0.2e-3, 2.6e13, 1e9, 51.2e9)

# TPU v5e target (DESIGN.md §2): 197 TFLOP/s bf16 ≈ 98.5e12 MAC/s, 819 GB/s
# HBM, ~2 µs launch, "CPU" = host fallback ~5e10 MAC/s.
TPU_V5E = HardwareProfile("tpu-v5e", 2e-6, 98.5e12, 5e10, 819e9)


@dataclass(frozen=True)
class CostModel:
    """Enforced (relaxed) delegation thresholds, paper §3.1."""

    profile: HardwareProfile = MOBILE_SOC
    min_ops: int = 3                 # N >= 3
    min_flops: float = 1e9           # F >= 1e9 MACs
    max_bytes_per_flop: float = 0.1  # B/F <= 0.1 bytes/MAC

    def accept(self, n_ops: int, flops: float, bytes_boundary: int) -> bool:
        if n_ops < self.min_ops:
            return False
        if flops < self.min_flops:
            return False
        if flops <= 0:
            return False
        return (bytes_boundary / flops) <= self.max_bytes_per_flop


@dataclass
class RegionStats:
    nodes: list
    n_ops: int
    flops: float
    boundary_bytes: int
    accepted: bool


@dataclass
class PartitionReport:
    regions: "list[RegionStats]" = field(default_factory=list)

    @property
    def accepted(self):
        return [r for r in self.regions if r.accepted]

    @property
    def rejected(self):
        return [r for r in self.regions if not r.accepted]


def assign_epochs(graph: Graph) -> "dict[int, int]":
    """Monotone epoch labels; even epochs = delegate-supported kind.

    Along every edge the epoch is non-decreasing and flips parity exactly
    when the supported/unsupported kind flips, so same-epoch node sets are
    convex (see module docstring).
    """
    preds, _ = graph.build_adjacency()
    epoch: dict[int, int] = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        want_parity = 0 if node.supported else 1
        m = max((epoch[p] for p in preds[nid]), default=-1)
        if m < 0:
            epoch[nid] = want_parity
        elif m % 2 == want_parity:
            epoch[nid] = m
        else:
            epoch[nid] = m + 1
    return epoch


def candidate_regions_epoch(graph: Graph) -> "list[set]":
    """Connected components of supported nodes within one epoch.

    This is what *stock* frameworks do (maximal delegation — the paper's
    "Post" graphs): regions may swallow independent parallel branches
    into one opaque delegate, destroying branch-level parallelism."""
    epoch = assign_epochs(graph)
    preds, succs = graph.build_adjacency()
    seen: set = set()
    regions: list[set] = []
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        if nid in seen or not node.supported:
            continue
        e = epoch[nid]
        comp = set()
        stack = [nid]
        while stack:
            v = stack.pop()
            if v in comp:
                continue
            comp.add(v)
            seen.add(v)
            for w in list(preds[v]) + list(succs[v]):
                if (w not in comp and graph.nodes[w].supported
                        and epoch[w] == e):
                    stack.append(w)
        regions.append(comp)
    return regions


def candidate_regions(graph: Graph) -> "list[set]":
    """Parallax candidates: maximal supported runs *within one branch*.

    Restricting delegate regions to branch chains (Fig. 1a/1b ordering)
    keeps sibling branches separate — a delegate never swallows the
    parallel structure the later stages exploit ("fine-grained subgraph
    control").  Chain runs are trivially convex, so fusion cannot create
    cycles."""
    from .classify import extract_branches

    regions: list[set] = []
    for br in extract_branches(graph):
        run: list = []
        for nid in br.nodes:
            if graph.nodes[nid].supported:
                run.append(nid)
            else:
                if run:
                    regions.append(set(run))
                run = []
        if run:
            regions.append(set(run))
    return regions


def partition_graph(graph: Graph, cost: "CostModel | None" = None,
                    scope: str = "branch"):
    """§3.1 delegate partitioning: fuse accepted regions, report the rest.

    ``scope="branch"`` (Parallax) keeps regions inside branch chains;
    ``scope="epoch"`` reproduces stock maximal delegation (the Table 7
    "Post" baseline).  Returns ``(new_graph, PartitionReport)``.  Rejected
    candidates are left as individual CPU-fallback nodes ("trims small
    delegated segments to reduce synchronization overhead", Fig. 1a).
    """
    cost = cost or CostModel()
    find = (candidate_regions if scope == "branch"
            else candidate_regions_epoch)
    report = PartitionReport()
    g = graph
    accepted: list[set] = []
    for region in find(graph):
        # N counts *original* ops: fused nodes carry their op count in
        # attrs["N"] (e.g. converter-fused SwiGLU pairs).
        n_ops = sum(graph.nodes[n].attrs.get("N", 1) for n in region)
        flops = sum(graph.nodes[n].flops for n in region)
        in_t, out_t = region_boundary_tensors(graph, region)
        # Boundary transfer excludes resident weights: params live on the
        # accelerator; only activations cross the boundary (§3.1's ∂S is the
        # tensor traffic between S and the rest of the running graph).
        param_ids = set(graph.params)
        b_bytes = sum(graph.tensors[t].nbytes() for t in in_t
                      if t not in param_ids)
        b_bytes += sum(graph.tensors[t].nbytes() for t in out_t)
        ok = cost.accept(n_ops, flops, b_bytes)
        report.regions.append(
            RegionStats(sorted(region), n_ops, flops, b_bytes, ok))
        if ok:
            accepted.append(region)
    for i, region in enumerate(accepted):
        g = fuse_region(g, region, name=f"delegate_{i}")
    return g, report
