"""DAG intermediate representation for Parallax graph analysis (paper §3.1).

The paper operates on a computation graph ``G = (V, E)`` where ``V`` are
operations and ``E`` are tensor dependencies.  This module provides that IR:

* :class:`TensorSpec` — static shape/dtype metadata (with optional symbolic,
  upper-bounded dynamic dimensions, §3.2 "Handling Dynamic Tensor Shapes"),
* :class:`Tensor` / :class:`Node` / :class:`Graph` — the DAG itself,
* :class:`GraphBuilder` — the API model exporters use to emit a graph,
* graph rewrite helpers used by delegate partitioning (region fusion).

Nodes carry an ``op_class`` drawn from the paper's Appendix A taxonomy
(conv / matmul / elementwise / pooling / misc / control_flow) plus the
post-partitioning ``delegate`` class for fused accelerator regions, and an
optional executable ``fn`` so plans can actually run (core/executor.py).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

# --------------------------------------------------------------------------
# Op taxonomy (paper Appendix A, Table 8) + structural classes
# --------------------------------------------------------------------------

OP_CLASSES = (
    "conv",          # Conv2D / DepthwiseConv2D
    "matmul",        # FullyConnected / MatMul / einsum contractions
    "elementwise",   # Add, Mul, ReLU, Sub, norm application, ...
    "pooling",       # AvgPool / MaxPool / Mean / Sum reductions
    "misc",          # Reshape / Slice / Transpose / Concat (0-FLOP-ish)
    "control_flow",  # If / While / dynamic ops -> forced Split-Merge (§3.1)
    "delegate",      # fused accelerator region (indivisible unit, §3.1)
)

# Structural labels from Algorithm 1 / Algorithm 3.
SEQUENTIAL = "Sequential"
SPLITTER = "Splitter"
MERGER = "Merger"
SPLIT_MERGE = "Split-Merge"


@dataclass(frozen=True)
class Dim:
    """A symbolic dynamic dimension with a static upper bound.

    The paper's memory estimator does *static shape inference* and sizes
    dynamic tensors by their originating branch's arena (§3.2); we size
    symbolic dims by ``bound`` so peak-memory estimates stay sound.
    """

    name: str
    bound: int

    def __int__(self) -> int:  # pragma: no cover - convenience
        return self.bound


def _dim_size(d: "int | Dim") -> int:
    return d.bound if isinstance(d, Dim) else int(d)


@dataclass(frozen=True)
class TensorSpec:
    shape: tuple
    dtype: str = "float32"

    @property
    def is_dynamic(self) -> bool:
        return any(isinstance(d, Dim) for d in self.shape)

    @property
    def static_shape(self) -> tuple:
        """Upper-bound concrete shape (symbolic dims resolved to bounds)."""
        return tuple(_dim_size(d) for d in self.shape)

    def numel(self) -> int:
        n = 1
        for d in self.static_shape:
            n *= d
        return n

    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)

    def nbytes(self) -> int:
        """B-term contribution: numel(T) * sizeof(dtype) (paper §3.1)."""
        return self.numel() * self.itemsize()


@dataclass
class Tensor:
    id: int
    spec: TensorSpec
    name: str = ""
    producer: "int | None" = None  # node id, None for graph inputs / params

    def nbytes(self) -> int:
        return self.spec.nbytes()


@dataclass
class Node:
    id: int
    name: str
    op_class: str
    inputs: tuple          # tensor ids read
    outputs: tuple         # tensor ids produced
    flops: float = 0.0     # Appendix A estimate (MACs*2 counted as FLOPs=MACs
                           # per paper's usage; we store MACs and call it F)
    fn: "Callable | None" = None   # (*arrays) -> tuple(arrays)
    attrs: dict = field(default_factory=dict)
    # True if this op can run inside an accelerator delegate region.  Dynamic
    # / control-flow / unsupported ops are False -> CPU fallback (paper §1).
    supported: bool = True

    def is_control_flow(self) -> bool:
        return self.op_class == "control_flow"


class Graph:
    """A static-single-producer DAG of :class:`Node` over :class:`Tensor`.

    Node-level edges are derived from tensor dependencies: ``u -> v`` iff
    some output tensor of ``u`` is an input of ``v``.
    """

    def __init__(self) -> None:
        self.tensors: dict[int, Tensor] = {}
        self.nodes: dict[int, Node] = {}
        self.inputs: list[int] = []    # graph-input tensor ids
        self.outputs: list[int] = []   # graph-output tensor ids
        self.params: list[int] = []    # weight tensor ids (excluded from
                                       # activation liveness, like the paper's
                                       # static model memory vs arena split)
        self._next_tensor = 0
        self._next_node = 0

    # -- construction ------------------------------------------------------

    def add_tensor(self, spec: TensorSpec, name: str = "",
                   producer: "int | None" = None) -> int:
        tid = self._next_tensor
        self._next_tensor += 1
        self.tensors[tid] = Tensor(tid, spec, name or f"t{tid}", producer)
        return tid

    def add_node(self, name: str, op_class: str, inputs: Sequence[int],
                 out_specs: Sequence[TensorSpec], flops: float = 0.0,
                 fn: "Callable | None" = None, supported: "bool | None" = None,
                 attrs: "dict | None" = None) -> Node:
        if op_class not in OP_CLASSES:
            raise ValueError(f"unknown op_class {op_class!r}")
        nid = self._next_node
        self._next_node += 1
        outs = tuple(self.add_tensor(s, f"{name}:o{i}", producer=nid)
                     for i, s in enumerate(out_specs))
        if supported is None:
            supported = op_class not in ("control_flow",)
        node = Node(nid, name, op_class, tuple(inputs), outs, float(flops),
                    fn, dict(attrs or {}), supported)
        self.nodes[nid] = node
        return node

    # -- topology ----------------------------------------------------------

    def producer_of(self, tid: int) -> "int | None":
        return self.tensors[tid].producer

    def consumers_of(self, tid: int) -> list:
        return [n.id for n in self.nodes.values() if tid in n.inputs]

    def build_adjacency(self):
        """Returns (preds, succs): node id -> sorted list of distinct node ids."""
        consumers: dict[int, list] = {t: [] for t in self.tensors}
        for n in self.nodes.values():
            for t in n.inputs:
                consumers[t].append(n.id)
        preds: dict[int, set] = {n: set() for n in self.nodes}
        succs: dict[int, set] = {n: set() for n in self.nodes}
        for n in self.nodes.values():
            for t in n.outputs:
                for c in consumers[t]:
                    succs[n.id].add(c)
                    preds[c].add(n.id)
        return ({k: sorted(v) for k, v in preds.items()},
                {k: sorted(v) for k, v in succs.items()})

    def topo_order(self) -> list:
        preds, succs = self.build_adjacency()
        indeg = {n: len(p) for n, p in preds.items()}
        # Deterministic Kahn: process lowest ids first.
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: list = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            changed = False
            for s in succs[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
                    changed = True
            if changed:
                ready.sort()
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def validate(self) -> None:
        for n in self.nodes.values():
            for t in list(n.inputs) + list(n.outputs):
                if t not in self.tensors:
                    raise ValueError(f"node {n.name}: unknown tensor {t}")
        for t in self.inputs + self.outputs + self.params:
            if t not in self.tensors:
                raise ValueError(f"unknown boundary tensor {t}")
        self.topo_order()  # raises on cycles

    # -- statistics (paper Table 7) -----------------------------------------

    def num_nodes(self) -> int:
        return len(self.nodes)

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes.values())

    # -- execution ----------------------------------------------------------

    def execute(self, env: "dict[int, Any]") -> "dict[int, Any]":
        """Reference op-by-op interpreter (topological order).

        ``env`` maps tensor id -> concrete array for all graph inputs and
        params.  Returns the completed environment.  Used as the oracle the
        Parallax executor is validated against.
        """
        env = dict(env)
        for nid in self.topo_order():
            node = self.nodes[nid]
            if node.fn is None:
                raise ValueError(f"node {node.name} has no fn")
            args = [env[t] for t in node.inputs]
            outs = node.fn(*args)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            if len(outs) != len(node.outputs):
                raise ValueError(
                    f"node {node.name}: fn returned {len(outs)} outputs, "
                    f"expected {len(node.outputs)}")
            for t, v in zip(node.outputs, outs):
                env[t] = v
        return env


class GraphBuilder:
    """Convenience layer used by models/dag_export.py."""

    def __init__(self) -> None:
        self.graph = Graph()

    def input(self, shape, dtype="float32", name="input") -> int:
        tid = self.graph.add_tensor(TensorSpec(tuple(shape), dtype), name)
        self.graph.inputs.append(tid)
        return tid

    def param(self, shape, dtype="float32", name="param") -> int:
        tid = self.graph.add_tensor(TensorSpec(tuple(shape), dtype), name)
        self.graph.params.append(tid)
        return tid

    def op(self, name, op_class, inputs, out_specs, flops=0.0, fn=None,
           supported=None, **attrs):
        node = self.graph.add_node(name, op_class, inputs, out_specs, flops,
                                   fn, supported, attrs)
        return node.outputs[0] if len(node.outputs) == 1 else node.outputs

    def mark_output(self, tid: int) -> None:
        self.graph.outputs.append(tid)

    def build(self) -> Graph:
        self.graph.validate()
        return self.graph


# --------------------------------------------------------------------------
# Region fusion (delegate partitioning rewrite, paper §3.1 / Fig. 1a)
# --------------------------------------------------------------------------


def region_boundary_tensors(graph: Graph, region: "set[int]"):
    """Boundary tensors ∂S of a node region S (paper §3.1).

    Returns (in_tensors, out_tensors): tensors crossing into / out of S.
    Params and graph inputs consumed by S count as in-boundary; tensors
    produced in S and consumed outside S (or graph outputs) as out-boundary.
    """
    produced = set()
    for nid in region:
        produced.update(graph.nodes[nid].outputs)
    in_t: list = []
    seen_in = set()
    for nid in sorted(region):
        for t in graph.nodes[nid].inputs:
            if t not in produced and t not in seen_in:
                seen_in.add(t)
                in_t.append(t)
    # consumers map once: O(V+E), not O(V^2)
    consumed_outside: set = set()
    for nid, node in graph.nodes.items():
        if nid in region:
            continue
        consumed_outside.update(node.inputs)
    out_t: list = []
    seen_out = set()
    graph_outputs = set(graph.outputs)
    for nid in sorted(region):
        for t in graph.nodes[nid].outputs:
            if ((t in consumed_outside or t in graph_outputs)
                    and t not in seen_out):
                seen_out.add(t)
                out_t.append(t)
    return in_t, out_t


def fuse_region(graph: Graph, region: "set[int]", name: str) -> Graph:
    """Rewrite ``graph`` with ``region`` collapsed into one delegate node.

    The fused node is *indivisible* for branch extraction (paper: "Delegate
    regions are treated as indivisible units").  Returns a new Graph sharing
    tensor ids with the original (tensors interior to the region survive but
    become unreferenced; boundary tensors keep their ids so downstream
    consumers are untouched).
    """
    in_t, out_t = region_boundary_tensors(graph, region)
    sub_order = [n for n in graph.topo_order() if n in region]
    F = sum(graph.nodes[n].flops for n in region)
    N = len(region)

    old = graph

    def delegate_fn(*args, _order=tuple(sub_order), _in=tuple(in_t),
                    _out=tuple(out_t)):
        env = dict(zip(_in, args))
        for nid in _order:
            node = old.nodes[nid]
            outs = node.fn(*[env[t] for t in node.inputs])
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            for t, v in zip(node.outputs, outs):
                env[t] = v
        return tuple(env[t] for t in _out)

    g = Graph()
    g.tensors = dict(graph.tensors)
    g.inputs = list(graph.inputs)
    g.outputs = list(graph.outputs)
    g.params = list(graph.params)
    g._next_tensor = graph._next_tensor
    g._next_node = graph._next_node

    for nid in graph.topo_order():
        if nid in region:
            continue
        g.nodes[nid] = graph.nodes[nid]
    # Delegate node reuses existing out-tensor ids (re-pointing producers).
    did = g._next_node
    g._next_node += 1
    dnode = Node(did, name, "delegate", tuple(in_t), tuple(out_t), F,
                 delegate_fn, {"fused_nodes": sorted(region), "N": N},
                 supported=True)
    g.nodes[did] = dnode
    for t in out_t:
        g.tensors[t] = dataclasses.replace(g.tensors[t], producer=did)
    g.validate()
    return g
