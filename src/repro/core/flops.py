"""Operator-level FLOP (MAC) estimators — paper Appendix A, Table 8.

The delegate cost model (§3.1) characterizes a region by its total compute
``F = Σ FLOPs(v)`` in MACs.  These estimators mirror Table 8:

=================  =========================  =====================================
Op class           Examples                   FLOPs per node
=================  =========================  =====================================
conv               Conv2D, DepthwiseConv2D    2·C_in·H_out·W_out·K_h·K_w·C_out
matmul             FullyConnected, MatMul     2·M·N·K
elementwise        Add, Mul, ReLU, Sub        output_size
pooling            AvgPool, MaxPool, Mean     H_out·W_out·K_h·K_w
misc               Reshape, Slice, Transpose  0  (optionally 0.5·output_size)
=================  =========================  =====================================

Unrecognized / non-compute-heavy ops are treated as 0-FLOP or assigned a
small constant workload (paper A.1).
"""

from __future__ import annotations

SMALL_CONSTANT_FLOPS = 1e3  # "small constant workload" for unknown ops


def conv2d_flops(c_in: int, h_out: int, w_out: int, k_h: int, k_w: int,
                 c_out: int, groups: int = 1) -> float:
    return 2.0 * (c_in // groups) * h_out * w_out * k_h * k_w * c_out


def matmul_flops(m: int, n: int, k: int, batch: int = 1) -> float:
    return 2.0 * batch * m * n * k


def elementwise_flops(output_size: int) -> float:
    return float(output_size)


def pooling_flops(h_out: int, w_out: int, k_h: int, k_w: int,
                  batch: int = 1, channels: int = 1) -> float:
    # Paper Table 8 lists the per-window cost; we scale by batch*channels so
    # region totals stay comparable across op classes.
    return float(h_out * w_out * k_h * k_w * batch * channels)


def misc_flops(output_size: int, count_half: bool = False) -> float:
    return 0.5 * output_size if count_half else 0.0


def attention_flops(batch: int, q_len: int, kv_len: int, num_q_heads: int,
                    head_dim: int) -> float:
    """softmax(QK^T)V as two batched matmuls (scores + context)."""
    return (matmul_flops(q_len, kv_len, head_dim, batch * num_q_heads)
            + matmul_flops(q_len, head_dim, kv_len, batch * num_q_heads))


def ssd_scan_flops(batch: int, seq: int, nheads: int, head_dim: int,
                   d_state: int) -> float:
    """Mamba2 SSD: per-step state update + output read-out, linear in seq."""
    return 2.0 * batch * seq * nheads * head_dim * d_state * 2
