"""ExecutionPlan — the artifact produced by the Parallax pipeline.

Bundles every §3 output: partitioned graph, branches with workload
metadata, layers, balanced groups, arena plans, and the resource-
constrained schedule, plus the graph statistics the paper reports in
Table 7 (Nodes / Layers / Par-Layers / Max-Branches).
"""

from __future__ import annotations

import functools
import hashlib
import types
from dataclasses import dataclass, field

from .arena import ArenaPlan
from .balance import LayerGroups
from .classify import Branch
from .graph import Graph
from .partition import PartitionReport
from .scheduler import Schedule


@dataclass
class GraphStats:
    """Table 7 row: structure + parallelism statistics of one graph."""

    nodes: int = 0
    layers: int = 0
    parallel_layers: int = 0     # layers with >= 2 mutually-independent branches
    max_branches: int = 0        # widest layer

    def as_row(self):
        return (self.nodes, self.layers, self.parallel_layers,
                self.max_branches)


@dataclass
class ExecutionPlan:
    graph: Graph
    branches: "dict[int, Branch]"
    layers: "list[list[int]]"                 # branch ids per layer
    layer_groups: "list[LayerGroups]"         # after §3.1 refinement
    arena_plans: "dict[int, ArenaPlan]"       # per-branch arenas (§3.2)
    schedule: Schedule                        # §3.3 greedy schedule
    partition_report: "PartitionReport | None" = None
    stats_pre: "GraphStats | None" = None     # original graph ("Pre")
    stats_post: "GraphStats | None" = None    # after delegation ("Post")
    stats_parallax: "GraphStats | None" = None
    # Heterogeneous device placement (repro.hetero) — None until the plan is
    # heterogenized; folded into plan_signature so placed plans never share
    # compiled artifacts with unplaced ones.
    placement: "object | None" = None         # hetero.placement.PlacementPlan
    attrs: dict = field(default_factory=dict)

    # -- memory accounting (Tables 4/5) ------------------------------------

    def sum_arena_sizes(self) -> int:
        """Branch-isolated footprint with in-branch reuse, no slab sharing."""
        return sum(p.size for p in self.arena_plans.values())

    def pooled_arena_peak(self) -> int:
        """Footprint with §3.2 cross-arena sharing: simulate the schedule
        acquiring/releasing slabs from one SlabPool."""
        from .arena import SlabPool
        pool = SlabPool()
        for sl in self.schedule.layers:
            live = []
            for group in sl.parallel_groups:
                slabs = [pool.acquire(self.arena_plans[b].size)
                         for b in group]
                live.extend(slabs)
            for bid in sl.sequential:
                s = pool.acquire(self.arena_plans[bid].size)
                pool.release(s)    # sequential branch frees immediately
            for s in live:
                pool.release(s)
        return pool.peak_bytes

    def scheduled_parallel_peak(self) -> int:
        """Worst-case concurrent memory the §3.3 schedule admits — must be
        <= budget (asserted by tests)."""
        peak = 0
        for sl in self.schedule.layers:
            for group in sl.parallel_groups:
                peak = max(peak, sum(self.branches[b].peak_memory
                                     for b in group))
        return peak


def _code_digest(code: "types.CodeType", h) -> None:
    h.update(code.co_code)
    h.update(" ".join(code.co_names).encode())   # co_code stores only name
    h.update(" ".join(code.co_varnames).encode())  # *indices*; hash the names
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            _code_digest(c, h)
        else:
            h.update(repr(c).encode())


def _value_token(v, depth: int = 0):
    """Fingerprint contribution of a default-arg / closure-cell value.

    Captured callables recurse through :func:`fn_fingerprint` (bounded, so
    self-referential closures of recursive functions terminate); arrays are
    deliberately reduced to (shape, dtype) metadata — hashing weight *values*
    per node would make signatures O(model size).  The compile cache
    compensates by scoping entries per graph object (core/compile.py), so
    two graphs whose fns close over different weights can never share
    compiled callables even though their signatures match.
    """
    if depth > 3:
        return type(v).__qualname__
    if callable(v):
        return fn_fingerprint(v, _depth=depth + 1)
    shape = getattr(v, "shape", None)
    if isinstance(shape, tuple) and hasattr(v, "dtype"):  # array-like only
        return ("array", shape, str(v.dtype))
    if isinstance(v, (tuple, list)):
        return tuple(_value_token(x, depth) for x in v)
    if isinstance(v, (int, float, str, bytes, bool, frozenset, type(None))):
        return repr(v)
    return type(v).__qualname__


def fn_fingerprint(fn, _depth: int = 0):
    """Stable fingerprint of a node's executable ``fn``.

    Hashes bytecode, referenced names, and constants (recursively through
    nested code objects), plus default arguments and closure-cell values
    via :func:`_value_token`, so two structurally identical graph builds
    produce the same fingerprint while different computations (``dot`` vs
    ``tanh(dot)``, ``exp`` vs ``log``) do not.
    """
    if fn is None:
        return None
    if isinstance(fn, functools.partial):
        return ("partial", fn_fingerprint(fn.func, _depth), repr(fn.args),
                repr(sorted(fn.keywords.items())))
    code = getattr(fn, "__code__", None)
    if code is None:  # builtin / callable object
        return ("callable", getattr(type(fn), "__qualname__", str(type(fn))))
    h = hashlib.blake2b(digest_size=16)
    _code_digest(code, h)
    h.update(repr(_value_token(getattr(fn, "__defaults__", None),
                               _depth)).encode())
    for cell in (getattr(fn, "__closure__", None) or ()):
        try:
            v = cell.cell_contents
        except ValueError:  # empty cell (still being initialized)
            v = "<empty-cell>"
        h.update(repr(_value_token(v, _depth)).encode())
    return (getattr(fn, "__qualname__", ""), h.hexdigest())


def plan_signature(plan: ExecutionPlan):
    """Hashable structural signature of a plan — the compile-cache key.

    Covers the graph (nodes, op classes, tensor wiring, shapes/dtypes, fn
    fingerprints), the branch decomposition, and the §3.3 schedule.  Two
    plans with equal signatures lower to the same fused callables, so the
    schedule compiler (core/compile.py) shares compiled artifacts across
    fresh executors and repeated ``compile_schedule`` calls.
    """
    g = plan.graph
    nodes = tuple(
        (nid, n.name, n.op_class, n.inputs, n.outputs, fn_fingerprint(n.fn))
        for nid, n in sorted(g.nodes.items()))
    tensors = tuple((tid, t.spec.static_shape, t.spec.dtype)
                    for tid, t in sorted(g.tensors.items()))
    branches = tuple((bid, tuple(b.nodes))
                     for bid, b in sorted(plan.branches.items()))
    sched = tuple(
        (sl.layer_index,
         tuple(tuple(grp) for grp in sl.parallel_groups),
         tuple(sl.sequential))
        for sl in plan.schedule.layers)
    io = (tuple(g.inputs), tuple(g.outputs), tuple(g.params))
    placement = (plan.placement.signature()
                 if plan.placement is not None else None)
    return (nodes, tensors, branches, sched, io, placement)


def graph_stats(graph: Graph) -> GraphStats:
    """Compute Table 7 statistics for any graph (Pre/Post/Parallax)."""
    from .classify import annotate_workloads, classify_nodes, extract_branches
    from .layers import build_layers

    labels = classify_nodes(graph)
    branches = extract_branches(graph, labels)
    annotate_workloads(graph, branches)
    layers = build_layers(graph, branches)
    par_layers = sum(1 for l in layers if len(l) >= 2)
    max_br = max((len(l) for l in layers), default=0)
    return GraphStats(graph.num_nodes(), len(layers), par_layers, max_br)
