"""ExecutionPlan — the artifact produced by the Parallax pipeline.

Bundles every §3 output: partitioned graph, branches with workload
metadata, layers, balanced groups, arena plans, and the resource-
constrained schedule, plus the graph statistics the paper reports in
Table 7 (Nodes / Layers / Par-Layers / Max-Branches).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .arena import ArenaPlan
from .balance import LayerGroups
from .classify import Branch
from .graph import Graph
from .partition import PartitionReport
from .scheduler import Schedule


@dataclass
class GraphStats:
    """Table 7 row: structure + parallelism statistics of one graph."""

    nodes: int = 0
    layers: int = 0
    parallel_layers: int = 0     # layers with >= 2 mutually-independent branches
    max_branches: int = 0        # widest layer

    def as_row(self):
        return (self.nodes, self.layers, self.parallel_layers,
                self.max_branches)


@dataclass
class ExecutionPlan:
    graph: Graph
    branches: "dict[int, Branch]"
    layers: "list[list[int]]"                 # branch ids per layer
    layer_groups: "list[LayerGroups]"         # after §3.1 refinement
    arena_plans: "dict[int, ArenaPlan]"       # per-branch arenas (§3.2)
    schedule: Schedule                        # §3.3 greedy schedule
    partition_report: "PartitionReport | None" = None
    stats_pre: "GraphStats | None" = None     # original graph ("Pre")
    stats_post: "GraphStats | None" = None    # after delegation ("Post")
    stats_parallax: "GraphStats | None" = None
    attrs: dict = field(default_factory=dict)

    # -- memory accounting (Tables 4/5) ------------------------------------

    def sum_arena_sizes(self) -> int:
        """Branch-isolated footprint with in-branch reuse, no slab sharing."""
        return sum(p.size for p in self.arena_plans.values())

    def pooled_arena_peak(self) -> int:
        """Footprint with §3.2 cross-arena sharing: simulate the schedule
        acquiring/releasing slabs from one SlabPool."""
        from .arena import SlabPool
        pool = SlabPool()
        for sl in self.schedule.layers:
            live = []
            for group in sl.parallel_groups:
                slabs = [pool.acquire(self.arena_plans[b].size)
                         for b in group]
                live.extend(slabs)
            for bid in sl.sequential:
                s = pool.acquire(self.arena_plans[bid].size)
                pool.release(s)    # sequential branch frees immediately
            for s in live:
                pool.release(s)
        return pool.peak_bytes

    def scheduled_parallel_peak(self) -> int:
        """Worst-case concurrent memory the §3.3 schedule admits — must be
        <= budget (asserted by tests)."""
        peak = 0
        for sl in self.schedule.layers:
            for group in sl.parallel_groups:
                peak = max(peak, sum(self.branches[b].peak_memory
                                     for b in group))
        return peak


def graph_stats(graph: Graph) -> GraphStats:
    """Compute Table 7 statistics for any graph (Pre/Post/Parallax)."""
    from .classify import annotate_workloads, classify_nodes, extract_branches
    from .layers import build_layers

    labels = classify_nodes(graph)
    branches = extract_branches(graph, labels)
    annotate_workloads(graph, branches)
    layers = build_layers(graph, branches)
    par_layers = sum(1 for l in layers if len(l) >= 2)
    max_br = max((len(l) for l in layers), default=0)
    return GraphStats(graph.num_nodes(), len(layers), par_layers, max_br)
